"""Paged serving path end-to-end: greedy parity vs the contiguous stack,
masked (right-pad) prefill, the block-granular Scheduler, prefix-cache
reuse, and preempt-to-recompute (PR 3, DESIGN §7)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BlockSpec, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import RequestPool, Scheduler, Server
from repro.dist import sharding as shd
from repro.nn.transformer import TransformerLM
from repro.serve.paged_kv import (PagedConfig, PagedDenseKVCache,
                                  PagedWindowKVCache)


def hybrid_cfg(window: int = 16, sparsity: int = 4, k_fixed: int = 0):
    """The acceptance config: dense + window + MoSA layers in one stack."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                     sparsity=sparsity)
    mosa = cfg.mosa if not k_fixed else dataclasses.replace(cfg.mosa,
                                                            k_fixed=k_fixed)
    return dataclasses.replace(
        cfg, n_layers=3, mosa=mosa,
        attention=dataclasses.replace(cfg.attention, window=window),
        pattern=(BlockSpec("attn", "dense"), BlockSpec("attn_local", "dense"),
                 BlockSpec("mosa", "dense")))


def dense_window_cfg(window: int = 16):
    """Stateless-prefix config (no MoSA): prefix-cache hits are exact."""
    cfg = get_config("mosa-paper", preset="smoke", variant="dense")
    return dataclasses.replace(
        cfg, n_layers=2,
        attention=dataclasses.replace(cfg.attention, window=window),
        pattern=(BlockSpec("attn", "dense"),
                 BlockSpec("attn_local", "dense")))


# --------------------------------------------------------- decode parity
def test_paged_generate_greedy_parity_hybrid():
    """Acceptance: paged decode is numerically exact vs contiguous decode —
    greedy token parity on the hybrid config (dense + window + MoSA)."""
    cfg = hybrid_cfg()
    B, ML, P, G = 2, 64, 11, 12
    contig = Server(cfg, batch=B, max_len=ML)
    paged = Server(cfg, batch=B, max_len=ML, params=contig.params,
                   paged=PagedConfig(block_size=8))
    prompts = jax.random.randint(jax.random.PRNGKey(0), (B, P), 2, cfg.vocab)
    t1, _ = contig.generate(prompts, G)
    t2, _ = paged.generate(prompts, G)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_paged_decode_many_matches_stepwise():
    """The fused chunk decoder emits the per-token loop's tokens on paged
    caches too (scan-fused decode over paged appends + kernel/ref path)."""
    cfg = hybrid_cfg()
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, P, G = 2, 8, 5
    prompts = jax.random.randint(key, (B, P), 2, cfg.vocab)
    paged = PagedConfig(block_size=8)

    caches = model.init_cache(B, 32, jnp.float32, paged=paged)
    lp, c0 = model.prefill(params, prompts, caches)
    tok0 = jnp.argmax(lp[:, -1], -1).astype(jnp.int32)[:, None]
    tok, cs, step = tok0, c0, []
    for _ in range(G):
        lg, cs = model.decode_step(params, tok, cs)
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        step.append(tok)
    caches = model.init_cache(B, 32, jnp.float32, paged=paged)
    _, c0 = model.prefill(params, prompts, caches)
    fused, _ = jax.jit(model.decode_many, static_argnames=("n",))(
        params, tok0, c0, None, n=G)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(step, 1)),
                                  np.asarray(fused))


# ------------------------------------------------------- masked prefill
def test_masked_prefill_padded_equals_unpadded():
    """Regression for the left-pad bug: a right-padded bucket prefill with
    a valid mask produces the SAME logits and greedy continuation as the
    unpadded prompt (pads out of attention, selection, and cache lengths).
    k_fixed pins the MoSA selection width so bucketing cannot change k."""
    cfg = hybrid_cfg(k_fixed=8)
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, P, ML, bucket, G = 2, 10, 64, 16, 8
    prompts = jax.random.randint(key, (B, P), 2, cfg.vocab)

    c1 = model.init_cache(B, ML, jnp.float32)
    l1, c1 = model.prefill(params, prompts, c1)
    padded = jnp.pad(prompts, ((0, 0), (0, bucket - P)))
    valid = jnp.broadcast_to(jnp.arange(bucket)[None] < P, (B, bucket))
    c2 = model.init_cache(B, ML, jnp.float32)
    l2, c2 = model.prefill(params, padded, c2, valid=valid,
                           last_pos=jnp.full((B,), P - 1))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-5, rtol=2e-5)
    t1 = jnp.argmax(l1[:, -1], -1).astype(jnp.int32)[:, None]
    t2 = jnp.argmax(l2[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(G):
        g1, c1 = model.decode_step(params, t1, c1)
        g2, c2 = model.decode_step(params, t2, c2)
        t1 = jnp.argmax(g1[:, -1], -1).astype(jnp.int32)[:, None]
        t2 = jnp.argmax(g2[:, -1], -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2),
                                      err_msg=f"step {i}")


def test_request_pool_right_pad_serves_mosa():
    """The continuous-batching pool path (bucketed single-row prefill ->
    write_slot) on a MoSA config: served output matches an unpadded
    whole-batch generate for a prompt whose bucket adds pads."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    cfg = dataclasses.replace(cfg, mosa=dataclasses.replace(cfg.mosa,
                                                            k_fixed=8))
    server = Server(cfg, batch=1, max_len=32)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (5,), 2, cfg.vocab)
    want, _ = server.generate(prompt[None], 6)          # unpadded reference
    pool = RequestPool(server)                          # buckets 5 -> 8
    rid = pool.submit(prompt, max_new=6)
    out = pool.run()
    np.testing.assert_array_equal(np.asarray(out[rid]),
                                  np.asarray(want[0]))


# ------------------------------------------------------------- scheduler
def test_scheduler_serves_mixed_lengths():
    cfg = hybrid_cfg()
    B = 2
    server = Server(cfg, batch=B, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=24,
                                      num_window_blocks=2 * B))
    sched = Scheduler(server, chunk=4)
    want = {}
    for i in range(4):
        rid = sched.submit(jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(4), i), (5 + 3 * i,), 2, cfg.vocab),
            max_new=3 + i)
        want[rid] = 3 + i
    out = sched.run()
    assert {k: len(v) for k, v in out.items()} == want
    # every block returns except the prefix-trie's retained entries
    assert sched.dense_pool.free_blocks + sched.prefix.n_nodes == \
        sched.dense_pool.num_blocks
    assert sched.window_pool.free_blocks == sched.window_pool.num_blocks


def test_scheduler_prefix_hit_exact_and_no_recompute():
    """Acceptance: a shared-prefix batch is served WITHOUT recomputing the
    shared blocks, and (on a stateless-prefix dense+window model) the hit
    path emits exactly the no-prefix-cache tokens."""
    cfg = dense_window_cfg()
    B = 2
    paged = PagedConfig(block_size=8, num_blocks=32, num_window_blocks=2 * B)
    server = Server(cfg, batch=B, max_len=64, paged=paged)
    shared = jax.random.randint(jax.random.PRNGKey(5), (17,), 2, cfg.vocab)
    sufs = [jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(6), i),
                               (3,), 2, cfg.vocab) for i in range(3)]

    on = Scheduler(server, chunk=4, prefix_cache=True)
    for s in sufs:
        on.submit(jnp.concatenate([shared, s]), max_new=5)
    got = on.run()
    assert on.stats["prefix_hits"] >= 2
    assert on.stats["prefix_hit_tokens"] >= 2 * 16
    # shared span prefilled once, not three times
    assert on.stats["prefilled_tokens"] <= 20 + 3 * 8

    server2 = Server(cfg, batch=B, max_len=64, paged=paged,
                     params=server.params)
    off = Scheduler(server2, chunk=4, prefix_cache=False)
    for s in sufs:
        off.submit(jnp.concatenate([shared, s]), max_new=5)
    want = off.run()
    for rid in want:
        np.testing.assert_array_equal(np.asarray(got[rid]),
                                      np.asarray(want[rid]),
                                      err_msg=f"request {rid}")


def test_scheduler_preempts_to_recompute_and_completes():
    """Exhausting the dense pool mid-decode preempts the latest-admitted
    request (blocks freed, prompt+generated requeued) and everything still
    runs to its full max_new."""
    cfg = hybrid_cfg()
    B = 2
    server = Server(cfg, batch=B, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=5,
                                      num_window_blocks=2 * B))
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    for i in range(2):
        sched.submit(jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(7), i), (10,), 2, cfg.vocab), max_new=12)
    out = sched.run()
    assert {k: len(v) for k, v in out.items()} == {0: 12, 1: 12}
    assert sched.stats["preemptions"] >= 1
    assert sched.dense_pool.free_blocks == sched.dense_pool.num_blocks


def test_mosa_prefill_past_matches_one_shot():
    """Layer-level: prefill(prefix) + prefill_past(suffix) reproduces the
    one-shot training-style prefill EXACTLY — under a constant-k schedule
    (k_fixed) and under the growing T/rho schedule (capacity-wide boundary
    storage, DESIGN §9; clamping stored width to the chunk-local k was the
    growing-k under-selection bug)."""
    from repro.configs.base import MoSAConfig
    from repro.core.kv_cache import MoSAKVCache
    from repro.core.mosa import MoSAAttention

    key = jax.random.PRNGKey(12)
    B, P, n = 2, 14, 8
    x = jax.random.normal(key, (B, P, 64), jnp.float32)

    # constant k: bitwise-equal selection, close K/V and suffix outputs
    cfgk = MoSAConfig(n_mosa_heads=3, sparsity=4, n_dense_heads=0,
                      d_head=8, k_fixed=6)
    layer = MoSAAttention(64, cfgk)
    params = layer.init(key)
    c1 = MoSAKVCache.create(B, 3, 6, 8, jnp.float32)
    y1, c1 = layer.prefill(params, x, c1)
    c2 = MoSAKVCache.create(B, 3, 6, 8, jnp.float32)
    _, c2 = layer.prefill(params, x[:, :n], c2)
    y2s, c2 = layer.prefill_past(params, x[:, n:], c2)
    np.testing.assert_array_equal(np.asarray(c1.idx), np.asarray(c2.idx))
    np.testing.assert_allclose(np.asarray(c1.scores), np.asarray(c2.scores),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1.k), np.asarray(c2.k),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y1[:, n:]), np.asarray(y2s),
                               atol=1e-4, rtol=1e-4)

    # growing k = T/rho: chunked == one-shot bit-exact too.  A prefix token
    # whose boundary rank is in (k_for(chunk), capacity] must survive the
    # boundary so a later, larger k_for(total) can re-admit it.
    cfgg = MoSAConfig(n_mosa_heads=3, sparsity=4, n_dense_heads=0,
                      d_head=8, min_k=2)
    layerg = MoSAAttention(64, cfgg)
    paramsg = layerg.init(key)
    kc = 8                                  # capacity > k_for(14) == 3
    g1 = MoSAKVCache.create(B, 3, kc, 8, jnp.float32)
    yg1, g1 = layerg.prefill(paramsg, x, g1)
    g2 = MoSAKVCache.create(B, 3, kc, 8, jnp.float32)
    _, g2 = layerg.prefill(paramsg, x[:, :n], g2)
    yg2s, g2 = layerg.prefill_past(paramsg, x[:, n:], g2)
    np.testing.assert_array_equal(np.asarray(g1.idx), np.asarray(g2.idx))
    np.testing.assert_allclose(np.asarray(g1.scores), np.asarray(g2.scores),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1.k), np.asarray(g2.k),
                               atol=1e-5, rtol=1e-5)
    # suffix outputs still use the one-shot k_for(total) selection width
    np.testing.assert_allclose(np.asarray(yg1[:, n:]), np.asarray(yg2s),
                               atol=1e-4, rtol=1e-4)
    # boundary storage is capacity-wide (min(kc, P) valid entries per head)
    n_sel = (np.asarray(g2.idx) >= 0).sum(-1)
    assert (n_sel == min(kc, P)).all(), n_sel


def test_scheduler_preemption_tokens_exact_dense_window():
    """Preempt-to-recompute must be INVISIBLE in the output for causal
    (dense+window) models: a run forced through preemption emits exactly
    the tokens of an uncontended run.  This also guards the freed-block
    hygiene — a finished or preempted row whose device block table still
    pointed at freed (then reallocated) blocks would corrupt a live row's
    KV and change its greedy tokens."""
    cfg = dense_window_cfg()
    B = 2
    prompts = [jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(11),
                                                     i), (10,), 2, cfg.vocab)
               for i in range(3)]
    big = Server(cfg, batch=B, max_len=64,
                 paged=PagedConfig(block_size=8, num_blocks=32,
                                   num_window_blocks=2 * B))
    ref_sched = Scheduler(big, chunk=4, prefix_cache=False)
    for pr in prompts:
        ref_sched.submit(pr, max_new=14)
    want = ref_sched.run()
    assert ref_sched.stats["preemptions"] == 0

    tight = Server(cfg, batch=B, max_len=64, params=big.params,
                   paged=PagedConfig(block_size=8, num_blocks=5,
                                     num_window_blocks=2 * B))
    sched = Scheduler(tight, chunk=4, prefix_cache=False)
    for pr in prompts:
        sched.submit(pr, max_new=14)
    out = sched.run()
    assert sched.stats["preemptions"] >= 1
    for rid in want:
        np.testing.assert_array_equal(np.asarray(out[rid]),
                                      np.asarray(want[rid]),
                                      err_msg=f"request {rid}")


def test_scheduler_honors_eos():
    cfg = hybrid_cfg()
    server = Server(cfg, batch=2, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=24,
                                      num_window_blocks=4))
    probe = Scheduler(server, prefix_cache=False)
    prompt = jax.random.randint(jax.random.PRNGKey(8), (6,), 2, cfg.vocab)
    probe.submit(prompt, max_new=8)
    ref = probe.run()
    eos = int(ref[0][2])

    server2 = Server(cfg, batch=2, max_len=64, params=server.params,
                     paged=PagedConfig(block_size=8, num_blocks=24,
                                       num_window_blocks=4))
    sched = Scheduler(server2, eos=eos, prefix_cache=False)
    sched.submit(prompt, max_new=8)
    out = sched.run()
    t = np.asarray(out[0])
    assert t[-1] == eos and (t[:-1] != eos).all() and len(t) <= 8


def test_scheduler_prefix_pure_dense_snapshot_free_depth():
    """Pure paged-dense model: per-row state is table + length only, so a
    hit can land on ANY chain depth — including mid-chain nodes that carry
    no snapshot — and stays exact."""
    cfg = dataclasses.replace(
        get_config("mosa-paper", preset="smoke", variant="dense"),
        n_layers=2)
    B = 2
    paged = PagedConfig(block_size=8, num_blocks=32, num_window_blocks=0)
    server = Server(cfg, batch=B, max_len=64, paged=paged)
    shared = jax.random.randint(jax.random.PRNGKey(9), (17,), 2, cfg.vocab)
    tail = jax.random.randint(jax.random.PRNGKey(10), (2,), 2, cfg.vocab)
    prompts = [shared,                                  # inserts the chain
               jnp.concatenate([shared[:12], tail])]    # mid-chain hit @8

    on = Scheduler(server, chunk=4, prefix_cache=True)
    assert not on.need_snapshot
    for pr in prompts:
        on.submit(pr, max_new=5)
    got = on.run()
    assert on.stats["prefix_hits"] >= 1

    server2 = Server(cfg, batch=B, max_len=64, paged=paged,
                     params=server.params)
    off = Scheduler(server2, chunk=4, prefix_cache=False)
    for pr in prompts:
        off.submit(pr, max_new=5)
    want = off.run()
    for rid in want:
        np.testing.assert_array_equal(np.asarray(got[rid]),
                                      np.asarray(want[rid]))


# --------------------------------------------------------------- artifact
def test_bench_serve_records_paged_acceptance():
    """Acceptance: BENCH_serve.json records >=1.5x max concurrent requests
    at a fixed cache-memory budget vs the contiguous slab path, and the
    trajectory has grown a second datapoint."""
    import json
    from pathlib import Path
    path = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    assert path.exists(), "run `make bench-smoke`"
    res = json.loads(path.read_text())
    cap = res["paged"]["capacity"]
    assert cap["capacity_ratio"] >= 1.5, cap
    assert cap["paged_max_concurrent"] >= \
        1.5 * cap["contiguous_max_concurrent"]
    assert len(res.get("trajectory", [])) >= 2
    # Mixed-length family (ISSUE 6 acceptance): chunked packed prefill
    # keeps >=95% of its chunk slots doing real work on a length-skewed
    # mix — the deleted pow2 bucketing managed ~70% — and TTFT is
    # recorded per request (p50 <= p99, both positive).
    mx = res["mixed"]
    assert mx["packed_efficiency"] >= 0.95, mx
    assert mx["packed_efficiency"] > mx["pow2_bucket_efficiency"], mx
    assert 0 < mx["ttft_s_p50"] <= mx["ttft_s_p99"], mx
    assert mx["requests"] >= 8 and mx["prefill_chunks"] > 0, mx


# --------------------------------------------------------------- sharding
def test_paged_cache_axes_head_shard_over_model():
    """Paged pools head-shard over ``model`` like their contiguous
    counterparts; the block dim stays replicated; tables follow batch."""
    mesh = make_host_mesh(tp=1)
    dense = jax.eval_shape(lambda: PagedDenseKVCache.create(
        2, 32, 4, 16, jnp.float32, block_size=8))
    spec = shd.cache_spec(dense, mesh, "tp")
    assert spec.k[0] is None and spec.k[2] == "model"
    assert spec.block_table[0] is not None            # batch axes
    win = jax.eval_shape(lambda: PagedWindowKVCache.create(
        2, 16, 4, 16, jnp.float32, block_size=8))
    wspec = shd.cache_spec(win, mesh, "tp")
    assert wspec.k[2] == "model" and wspec.positions[0] is not None

    # through the full tree path, stacked caches shift by the layer axis
    stacked = jax.eval_shape(lambda: jax.tree.map(
        lambda t: jnp.zeros((3,) + t.shape, t.dtype), dense))
    sh = shd.cache_shardings({"scan": {"pos0": stacked}}, mesh, "tp")
    assert sh["scan"]["pos0"].k.spec[3] == "model"


def test_paged_server_cache_tree_shardings_resolve():
    cfg = hybrid_cfg()
    mesh = make_host_mesh(tp=1)
    model = TransformerLM(cfg)
    shapes = jax.eval_shape(lambda: model.init_cache(
        2, 32, jnp.float32, paged=PagedConfig(block_size=8)))
    sh = shd.cache_shardings(shapes, mesh, "tp")
    assert jax.tree.structure(shapes) == jax.tree.structure(
        jax.tree.map(lambda x: 0, sh))


# ------------------------------------------------------- lazy window ring
def test_lazy_window_ring_allocator_invariant():
    """Lazy ring allocation (ROADMAP open item): admission takes only the
    ring blocks the prompt's tokens write — ceil(min(P, W) / bs), not the
    full ring — decode growth extends the cover ahead of each chunk, the
    cover saturates once the ring wraps, and EVERY recorded ring position
    is backed by an allocated block (allocation-precedes-write: a write
    through a -1 table entry would drop the KV but keep the position,
    making decode read junk)."""
    from repro.dist import hints

    cfg = hybrid_cfg(window=16)              # W=16, bs=8 -> full ring = 2
    B = 2
    server = Server(cfg, batch=B, max_len=64,
                    paged=PagedConfig(block_size=8, num_blocks=24,
                                      num_window_blocks=2 * B))
    sched = Scheduler(server, chunk=4, prefix_cache=False)
    pool = sched.window_pool

    def backed_positions():
        leaves = jax.tree_util.tree_leaves(
            sched.caches,
            is_leaf=lambda x: isinstance(x, PagedWindowKVCache))
        for leaf in leaves:
            if not isinstance(leaf, PagedWindowKVCache):
                continue
            pos = np.asarray(leaf.positions)
            bt = np.asarray(leaf.block_table)
            bs = leaf.block_size
            for b in range(pos.shape[0]):
                slots = np.nonzero(pos[b] >= 0)[0]
                assert (bt[b][slots // bs] >= 0).all(), (b, slots, bt[b])

    prompt = jax.random.randint(jax.random.PRNGKey(13), (5,), 2, cfg.vocab)
    rid = sched.submit(prompt, max_new=20)
    with server.mesh, hints.sharding_hints(mesh=server.mesh):
        assert sched._admit(0, sched.queue.pop(0))
        # P=5 < bs=8: ONE ring block, not the full ring of 2
        assert len(sched._slots[0]["window_ids"]) == 1
        assert pool.live_blocks == 1
        backed_positions()

        # growth ahead of a 4-token chunk: 5+4=9 tokens -> 2 blocks
        assert sched._grow_row(0, 4, [0])
        assert len(sched._slots[0]["window_ids"]) == 2
        assert pool.live_blocks == 2
        # ring saturated: a huge chunk allocates nothing more
        assert sched._grow_row(0, 40, [0])
        assert len(sched._slots[0]["window_ids"]) == 2
        assert pool.live_blocks == 2
        backed_positions()

        sched._finish(0)
    assert pool.free_blocks == pool.num_blocks

    # end-to-end: a full scheduler run over mixed lengths stays token-parity
    # with the eager-ring behavior (same greedy tokens as an uncontended
    # reference run) and returns every ring block.
    server2 = Server(cfg, batch=B, max_len=64, params=server.params,
                     paged=PagedConfig(block_size=8, num_blocks=24,
                                       num_window_blocks=2 * B))
    s2 = Scheduler(server2, chunk=4, prefix_cache=False)
    for i in range(3):
        s2.submit(jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(14), i), (4 + 7 * i,), 2, cfg.vocab),
            max_new=6)
    out = s2.run()
    assert {len(v) for v in out.values()} == {6}
    assert s2.window_pool.free_blocks == s2.window_pool.num_blocks
