"""Varlen (cu_seqlens) parity suite — ISSUE 6 acceptance.

The packed ragged path must be exact against the per-row padded path at
every level it exists: the flash varlen kernel, the fused MoSA kernels
(fwd AND bwd, through the custom_vjp), the paged prefill kernel, the
packed cache appends, the model-level chunked ``prefill_packed``, and the
chunked-prefill scheduler (decode rows stay live during a long prompt and
tokens match unchunked greedy).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BlockSpec, get_config
from repro.kernels import ops, ref
from repro.nn.transformer import TransformerLM
from repro.serve.paged_attention import paged_prefill_attention
from repro.serve.paged_kv import (PagedConfig, PagedDenseKVCache,
                                  PagedWindowKVCache)


def _cu(lens):
    return jnp.asarray(np.concatenate([[0], np.cumsum(lens)]), jnp.int32)


# ------------------------------------------------------------ flash varlen
@pytest.mark.parametrize("window", [0, 8])
def test_flash_varlen_matches_per_row_padded(window):
    """Packed stream == per-row path, segment by segment (fp32)."""
    lens = [13, 5, 22, 1]
    Hq, Hkv, d = 4, 2, 16
    total = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (total, Hq, d))
    k = jax.random.normal(ks[1], (total, Hkv, d))
    v = jax.random.normal(ks[2], (total, Hkv, d))
    cu = _cu(lens)

    out = np.asarray(ops.flash_attention_varlen(q, k, v, cu, window=window))
    np.testing.assert_allclose(
        out, np.asarray(ref.flash_attention_varlen_ref(q, k, v, cu,
                                                       window=window)),
        atol=1e-5, rtol=1e-5)
    # the per-row PADDED kernel path: right-pad every segment to max(lens)
    Pm = max(lens)
    for i, L in enumerate(lens):
        s = int(cu[i])
        pad = lambda x: jnp.pad(x[s:s + L].transpose(1, 0, 2),
                                ((0, 0), (0, Pm - L), (0, 0)))[None]
        o_pad = ops.flash_attention(pad(q), pad(k), pad(v), window=window)
        np.testing.assert_allclose(out[s:s + L],
                                   np.asarray(o_pad[0, :, :L].transpose(
                                       1, 0, 2)),
                                   atol=1e-5, rtol=1e-5, err_msg=f"seg {i}")


def test_flash_varlen_bf16():
    lens = [9, 31]
    Hq, Hkv, d = 2, 2, 32
    total = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (total, Hq, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (total, Hkv, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (total, Hkv, d), jnp.bfloat16)
    cu = _cu(lens)
    out = ops.flash_attention_varlen(q, k, v, cu)
    assert out.dtype == jnp.bfloat16
    want = ref.flash_attention_varlen_ref(q.astype(jnp.float32),
                                          k.astype(jnp.float32),
                                          v.astype(jnp.float32), cu)
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(want), atol=2e-2, rtol=2e-2)


# -------------------------------------------------------- MoSA seg kernels
def _mosa_seg_inputs(key, H, d, lens, rho, dtype):
    """Packed-stream MoSA inputs: per-head sorted selections drawn from the
    whole stream; seg ids follow each selected token's segment."""
    T = sum(lens)
    S = max(T // rho, 2)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (1, H, S, d), dtype)
    k = jax.random.normal(ks[1], (1, H, S, d), dtype)
    v = jax.random.normal(ks[2], (1, H, S, d), dtype)
    perm = jnp.stack([jax.random.permutation(
        jax.random.fold_in(ks[3], h), T)[:S] for h in range(H)])
    idx = jnp.sort(perm, axis=-1).astype(jnp.int32)[None]         # (1,H,S)
    r = jax.nn.sigmoid(jax.random.normal(ks[4], (1, H, S))).astype(
        jnp.float32)
    seg_of_pos = jnp.asarray(np.repeat(np.arange(len(lens)), lens),
                             jnp.int32)
    seg = seg_of_pos[idx]
    return q, k, v, idx, r, seg


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mosa_seg_kernel_matches_oracle(dtype):
    q, k, v, idx, r, seg = _mosa_seg_inputs(jax.random.PRNGKey(2), 3, 16,
                                            [17, 40, 7], 2, dtype)
    out = ops.mosa_attention(q, k, v, idx, r, seg=seg)
    want = ref.mosa_attention_ref(q, k, v, idx, r, seg=seg)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(want, jnp.float32),
                               atol=tol, rtol=tol)
    # the seg mask genuinely bites: unsegmented output differs
    free = np.asarray(ref.mosa_attention_ref(q, k, v, idx, r), jnp.float32)
    assert np.abs(free - np.asarray(want, jnp.float32)).max() > 1e-3


def test_mosa_seg_kernel_grads_match_reference():
    """Fused bwd kernels under the segment mask (dq/dk/dv/dr) == autodiff
    of the seg-masked reference."""
    q, k, v, idx, r, seg = _mosa_seg_inputs(jax.random.PRNGKey(3), 2, 20,
                                            [11, 25], 2, jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.float32)

    def loss(fn):
        return lambda q, k, v, r: jnp.sum(
            fn(q, k, v, idx, r, seg=seg).astype(jnp.float32) * g)

    got = jax.grad(loss(ops.mosa_attention), argnums=(0, 1, 2, 3))(q, k, v,
                                                                   r)
    want = jax.grad(loss(ref.mosa_attention_ref),
                    argnums=(0, 1, 2, 3))(q, k, v, r)
    for name, a, b in zip("qkvr", got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                                   rtol=3e-5, err_msg=f"d{name}")


def test_mosa_layer_packed_grads_pallas_equals_einsum():
    """Full MoSAAttention layer on a PACKED row (segments + per-doc
    positions): fused-kernel parameter grads == einsum path."""
    from repro.configs.base import MoSAConfig
    from repro.core.mosa import MoSAAttention
    key = jax.random.PRNGKey(4)
    lens = [24, 40]
    x = jax.random.normal(key, (2, sum(lens), 32))
    segments = jnp.broadcast_to(
        jnp.asarray(np.repeat(np.arange(len(lens)), lens), jnp.int32),
        (2, sum(lens)))
    positions = jnp.broadcast_to(
        jnp.asarray(np.concatenate([np.arange(n) for n in lens]),
                    jnp.int32), (2, sum(lens)))
    cfg = MoSAConfig(n_mosa_heads=4, sparsity=8, n_dense_heads=0, d_head=16)
    m_ref = MoSAAttention(32, cfg, impl="einsum")
    m_fused = MoSAAttention(32, cfg, impl="pallas")
    p = m_ref.init(key)

    def loss(m):
        return lambda p: jnp.sum(jnp.square(
            m(p, x, positions, segments=segments)))

    g_ref = jax.grad(loss(m_ref))(p)
    g_fused = jax.grad(loss(m_fused))(p)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(g_ref)[0],
            jax.tree_util.tree_flatten_with_path(g_fused)[0]):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), atol=1e-4, rtol=1e-4,
            err_msg=jax.tree_util.keystr(path))


def test_lm_loss_packed_grads_pallas_equals_einsum():
    """LM-loss level on a packed batch (segments + positions): grads
    through the fused seg-masked kernels == einsum path."""
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa")
    cfg_f = dataclasses.replace(
        cfg, mosa=dataclasses.replace(cfg.mosa, impl="pallas"))
    key = jax.random.PRNGKey(5)
    lens = [20, 12]
    T = sum(lens)
    tokens = jax.random.randint(key, (2, T), 2, cfg.vocab)
    segments = jnp.broadcast_to(
        jnp.asarray(np.repeat(np.arange(len(lens)), lens), jnp.int32),
        (2, T))
    positions = jnp.broadcast_to(
        jnp.asarray(np.concatenate([np.arange(n) for n in lens]),
                    jnp.int32), (2, T))
    batch = {"tokens": tokens, "labels": tokens, "segments": segments,
             "positions": positions}
    m_ref, m_fused = TransformerLM(cfg), TransformerLM(cfg_f)
    params = m_ref.init(key)
    (l_ref, _), g_ref = jax.value_and_grad(m_ref.loss, has_aux=True)(
        params, batch)
    (l_fused, _), g_fused = jax.value_and_grad(m_fused.loss, has_aux=True)(
        params, batch)
    np.testing.assert_allclose(float(l_fused), float(l_ref), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-4, rtol=2e-4)


def test_packed_training_no_cross_doc_leakage():
    """Dense-attention model: the loss of a packed row [docA|docB] equals
    the loss of the two docs in separate (padded) rows — the segment mask
    is airtight, so packing is free of cross-doc contamination."""
    cfg = dataclasses.replace(
        get_config("mosa-paper", preset="smoke", variant="dense"),
        n_layers=2)
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(6))
    lens = [21, 11]
    T = sum(lens)
    docs = [jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(7), i),
                               (n,), 2, cfg.vocab)
            for i, n in enumerate(lens)]

    packed = {"tokens": jnp.concatenate(docs)[None],
              "labels": jnp.concatenate(docs)[None],
              "segments": jnp.asarray(
                  np.repeat(np.arange(len(lens)), lens), jnp.int32)[None],
              "positions": jnp.asarray(
                  np.concatenate([np.arange(n) for n in lens]),
                  jnp.int32)[None]}
    toks = np.zeros((2, T), np.int32)
    labels = np.full((2, T), -1, np.int32)
    for i, d in enumerate(docs):
        toks[i, :lens[i]] = np.asarray(d)
        labels[i, :lens[i]] = np.asarray(d)
    padded = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    (lp, _), gp = jax.value_and_grad(model.loss, has_aux=True)(params,
                                                               packed)
    (lu, _), gu = jax.value_and_grad(model.loss, has_aux=True)(params,
                                                               padded)
    np.testing.assert_allclose(float(lp), float(lu), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ paged varlen
def test_paged_prefill_attention_matches_per_row():
    """Packed paged prefill (two chunks, ragged rows, GQA) == per-row
    full-prefix flash reference."""
    B, Hq, Hkv, d, bs, ML = 3, 4, 2, 16, 8, 64
    lens = [19, 7, 26]
    split = [11, 7, 9]                     # chunk-1 sizes (row 1 completes)
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q_all = [jax.random.normal(jax.random.fold_in(ks[0], b), (L, Hq, d))
             for b, L in enumerate(lens)]
    k_all = [jax.random.normal(jax.random.fold_in(ks[1], b), (L, Hkv, d))
             for b, L in enumerate(lens)]
    v_all = [jax.random.normal(jax.random.fold_in(ks[2], b), (L, Hkv, d))
             for b, L in enumerate(lens)]

    cache = PagedDenseKVCache.create(B, ML, Hkv, d, jnp.float32,
                                     block_size=bs, identity_tables=True)
    got = [[] for _ in range(B)]
    for chunk in range(2):
        segs = [(b, 0 if chunk == 0 else split[b],
                 split[b] if chunk == 0 else lens[b] - split[b])
                for b in range(B)]
        segs = [(b, s, t) for b, s, t in segs if t > 0]
        qc = jnp.concatenate([q_all[b][s:s + t] for b, s, t in segs])
        kc = jnp.concatenate([k_all[b][s:s + t] for b, s, t in segs])
        vc = jnp.concatenate([v_all[b][s:s + t] for b, s, t in segs])
        row_of_tok = jnp.asarray(
            np.repeat([b for b, _, _ in segs], [t for _, _, t in segs]),
            jnp.int32)
        pos_of_tok = jnp.asarray(
            np.concatenate([np.arange(s, s + t) for _, s, t in segs]),
            jnp.int32)
        cu = _cu([t for _, _, t in segs])
        rows = jnp.asarray([b for b, _, _ in segs], jnp.int32)
        past = jnp.asarray([s for _, s, _ in segs], jnp.int32)
        cache = cache.append_packed(kc, vc, row_of_tok, pos_of_tok)
        out = paged_prefill_attention(qc, cache, cu, rows, past,
                                      scale=d ** -0.5)
        for i, (b, s, t) in enumerate(segs):
            got[b].append(np.asarray(out[int(cu[i]):int(cu[i + 1])]))

    for b in range(B):
        o = np.concatenate(got[b])                         # (L, Hq, d)
        want = ref.flash_attention_ref(
            q_all[b].transpose(1, 0, 2)[None],
            k_all[b].transpose(1, 0, 2)[None],
            v_all[b].transpose(1, 0, 2)[None])
        np.testing.assert_allclose(o, np.asarray(want[0].transpose(1, 0, 2)),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {b}")


def test_window_append_packed_matches_sequential():
    """Ring scatter parity: packed multi-row append (incl. a row longer
    than the window inside ONE stream) == the batched sequential append."""
    B, H, d, W, bs = 3, 2, 8, 16, 8
    lens = [5, 23, 16]                     # row 1 exceeds W in one stream
    key = jax.random.PRNGKey(10)
    kv = [jax.random.normal(jax.random.fold_in(key, b), (2, L, H, d))
          for b, L in enumerate(lens)]

    seq = PagedWindowKVCache.create(B, W, H, d, jnp.float32, block_size=bs,
                                    identity_tables=True)
    Pm = max(lens)
    kp = jnp.stack([jnp.pad(kv[b][0], ((0, Pm - lens[b]), (0, 0), (0, 0)))
                    for b in range(B)])
    vp = jnp.stack([jnp.pad(kv[b][1], ((0, Pm - lens[b]), (0, 0), (0, 0)))
                    for b in range(B)])
    seq = seq.append(kp, vp, n_valid=jnp.asarray(lens, jnp.int32))

    packed = PagedWindowKVCache.create(B, W, H, d, jnp.float32,
                                       block_size=bs, identity_tables=True)
    kc = jnp.concatenate([kv[b][0] for b in range(B)])
    vc = jnp.concatenate([kv[b][1] for b in range(B)])
    row_of_tok = jnp.asarray(np.repeat(np.arange(B), lens), jnp.int32)
    pos_of_tok = jnp.asarray(
        np.concatenate([np.arange(n) for n in lens]), jnp.int32)
    packed = packed.append_packed(kc, vc, row_of_tok, pos_of_tok)

    np.testing.assert_array_equal(np.asarray(seq.length),
                                  np.asarray(packed.length))
    np.testing.assert_array_equal(np.asarray(seq.positions),
                                  np.asarray(packed.positions))
    for name in ("k", "v"):
        np.testing.assert_allclose(np.asarray(getattr(seq, name)),
                                   np.asarray(getattr(packed, name)),
                                   err_msg=name)


# ----------------------------------------------------- model prefill_packed
def _hybrid_cfg(window=16, sparsity=4):
    cfg = get_config("mosa-paper", preset="smoke", variant="mosa",
                     sparsity=sparsity)
    return dataclasses.replace(
        cfg, n_layers=3,
        attention=dataclasses.replace(cfg.attention, window=window),
        pattern=(BlockSpec("attn", "dense"), BlockSpec("attn_local", "dense"),
                 BlockSpec("mosa", "dense")))


def test_model_prefill_packed_chunked_exact():
    """TransformerLM.prefill_packed streamed in ragged multi-row chunks ==
    per-row one-shot prefill: caches match the padded batch prefill, final
    logits match the per-row UNPADDED prefill (selection width is k_for of
    the row's REAL length — the pow2-bucket k_for(padded T) bug is gone)."""
    from repro.core.kv_cache import MoSAKVCache

    cfg = _hybrid_cfg()
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, ML, C = 3, 64, 16
    paged = PagedConfig(block_size=8)
    rng = np.random.default_rng(0)
    P = [19, 7, 26]
    prompts = [rng.integers(2, cfg.vocab, (p,)).astype(np.int32) for p in P]

    # reference caches: one-shot right-padded batch prefill
    caches = model.init_cache(B, ML, jnp.float32, paged=paged)
    Pm = max(P)
    toks = np.zeros((B, Pm), np.int32)
    valid = np.zeros((B, Pm), bool)
    for b, pr in enumerate(prompts):
        toks[b, :len(pr)] = pr
        valid[b, :len(pr)] = True
    _, c_ref = model.prefill(params, jnp.asarray(toks), caches,
                             valid=jnp.asarray(valid),
                             last_pos=jnp.asarray(
                                 [p - 1 for p in P], jnp.int32))

    # packed chunked prefill: greedy-pack pending rows into C-slot chunks
    caches = model.init_cache(B, ML, jnp.float32, paged=paged)
    done = [0] * B
    final_logits = {}
    N = 3
    while any(done[b] < P[b] for b in range(B)):
        segs, budget = [], C
        for b in range(B):
            rem = P[b] - done[b]
            if budget == 0 or len(segs) == N or rem == 0:
                continue
            take = min(rem, budget)
            segs.append((b, done[b], take))
            budget -= take
        buf = np.zeros((C,), np.int32)
        cu = np.zeros((N + 1,), np.int32)
        rows = np.full((N,), -1, np.int32)
        past = np.zeros((N,), np.int32)
        off = 0
        for i, (b, start, take) in enumerate(segs):
            buf[off:off + take] = prompts[b][start:start + take]
            rows[i], past[i] = b, start
            off += take
            cu[i + 1] = off
        cu[len(segs) + 1:] = off
        logits, caches = model.prefill_packed(
            params, jnp.asarray(buf)[None], caches, jnp.asarray(cu),
            jnp.asarray(rows), jnp.asarray(past))
        for i, (b, start, take) in enumerate(segs):
            done[b] += take
            if done[b] == P[b]:
                final_logits[b] = np.asarray(logits[i])

    def cmp_mosa(name, a, b):
        # K/V of empty slots (idx == -1) are junk by design — mask them
        np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx),
                                      err_msg=name + ".idx")
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores), atol=1e-5,
                                   err_msg=name + ".scores")
        ok = (np.asarray(a.idx) >= 0)[..., None]
        for f in ("k", "v"):
            np.testing.assert_allclose(
                np.asarray(getattr(a, f)) * ok,
                np.asarray(getattr(b, f)) * ok, atol=2e-4, rtol=1e-4,
                err_msg=name + "." + f)
        np.testing.assert_array_equal(np.asarray(a.length),
                                      np.asarray(b.length),
                                      err_msg=name + ".length")

    is_mosa = lambda x: isinstance(x, MoSAKVCache)
    for (pa, va), (_, vb) in zip(
            jax.tree_util.tree_flatten_with_path(c_ref, is_leaf=is_mosa)[0],
            jax.tree_util.tree_flatten_with_path(caches,
                                                 is_leaf=is_mosa)[0]):
        name = jax.tree_util.keystr(pa)
        if is_mosa(va):
            cmp_mosa(name, va, vb)
        elif np.asarray(va).dtype.kind in "fc":
            np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                       atol=2e-4, rtol=1e-4, err_msg=name)
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=name)

    # logits oracle: per-row UNPADDED prefill
    for b in range(B):
        c1 = model.init_cache(1, ML, jnp.float32, paged=paged)
        lp1, _ = model.prefill(params, jnp.asarray(prompts[b])[None], c1)
        np.testing.assert_allclose(final_logits[b], np.asarray(lp1[0, -1]),
                                   atol=2e-4, rtol=1e-4,
                                   err_msg=f"row {b} logits")


# -------------------------------------------------------------- scheduler
def test_scheduler_chunked_prefill_interleaves_and_matches_greedy():
    """A long prompt streams through chunk-budgeted packed prefill while a
    short request decodes BETWEEN its chunks (TTFT not stalled), and every
    request's greedy tokens equal the unchunked ``Server.generate``."""
    from repro.launch.serve import Server
    from repro.serve.scheduler import Scheduler

    cfg = _hybrid_cfg()
    B = 2
    paged = PagedConfig(block_size=8, num_blocks=24, num_window_blocks=2 * B)
    server = Server(cfg, batch=B, max_len=64, paged=paged)
    short = jax.random.randint(jax.random.PRNGKey(20), (4,), 2, cfg.vocab)
    long = jax.random.randint(jax.random.PRNGKey(21), (40,), 2, cfg.vocab)

    sched = Scheduler(server, chunk=4, chunk_tokens=8, max_prefill_segs=2,
                      prefix_cache=False)
    events = []
    real_pf, real_dm = server.prefill_packed, server.decode_many
    server.prefill_packed = (
        lambda *a, **kw: (events.append("P"), real_pf(*a, **kw))[1])
    server.decode_many = (
        lambda *a, **kw: (events.append("D"), real_dm(*a, **kw))[1])
    r_short = sched.submit(short, max_new=10)
    r_long = sched.submit(long, max_new=3)
    got = sched.run()

    # decode progressed while the long prompt was still prefilling: some
    # decode dispatch lands strictly BEFORE the last prefill chunk
    last_p = max(i for i, e in enumerate(events) if e == "P")
    assert any(e == "D" for e in events[:last_p]), events
    assert sched.stats["prefill_chunks"] >= 5, sched.stats

    ref_server = Server(cfg, batch=1, max_len=64,
                        paged=PagedConfig(block_size=8),
                        params=server.params)
    for rid, prompt, max_new in ((r_short, short, 10), (r_long, long, 3)):
        want, _ = ref_server.generate(prompt[None], max_new, temperature=0.0)
        np.testing.assert_array_equal(
            np.asarray(got[rid]), np.asarray(want[0, :len(got[rid])]),
            err_msg=f"rid {rid}")
        assert len(got[rid]) == max_new


def test_scheduler_slot_reuse_after_free():
    """Satellite: cycle ONE slot through admit -> finish -> admit with
    different prompt lengths; the recycled slot's tokens match a fresh
    scheduler, and freed rows leave no stale device state (-1 tables,
    full pools)."""
    from repro.launch.serve import Server
    from repro.serve.scheduler import Scheduler

    cfg = _hybrid_cfg()
    paged = PagedConfig(block_size=8, num_blocks=16, num_window_blocks=2)
    server = Server(cfg, batch=1, max_len=64, paged=paged)
    prompts = [jax.random.randint(jax.random.PRNGKey(30), (20,), 2,
                                  cfg.vocab),
               jax.random.randint(jax.random.PRNGKey(31), (7,), 2,
                                  cfg.vocab),
               jax.random.randint(jax.random.PRNGKey(32), (33,), 2,
                                  cfg.vocab)]

    sched = Scheduler(server, chunk=4, chunk_tokens=16, prefix_cache=False)
    rids = [sched.submit(p, max_new=5) for p in prompts]
    got = sched.run()                       # B=1: strictly sequential reuse

    for i, p in enumerate(prompts):
        server2 = Server(cfg, batch=1, max_len=64, paged=paged,
                         params=server.params)
        fresh = Scheduler(server2, chunk=4, chunk_tokens=16,
                          prefix_cache=False)
        rid = fresh.submit(p, max_new=5)
        want = fresh.run()[rid]
        np.testing.assert_array_equal(np.asarray(got[rids[i]]),
                                      np.asarray(want), err_msg=f"req {i}")

    # -1-table invariant after the last free
    assert sched.dense_pool.free_blocks == sched.dense_pool.num_blocks
    assert sched.window_pool.free_blocks == sched.window_pool.num_blocks
    for leaf in jax.tree_util.tree_leaves(
            sched.caches, is_leaf=lambda x: isinstance(
                x, (PagedDenseKVCache, PagedWindowKVCache))):
        if isinstance(leaf, (PagedDenseKVCache, PagedWindowKVCache)):
            assert (np.asarray(leaf.block_table) == -1).all()
            assert (np.asarray(leaf.length) == 0).all()
