"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs.  One test per assigned arch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, config_names
from repro.nn.transformer import TransformerLM

ARCHS = [
    "granite-moe-1b-a400m", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
    "musicgen-large", "yi-34b", "yi-9b", "gemma3-4b", "qwen2-1.5b",
    "xlstm-125m", "qwen2-vl-72b",
]


def _batch(cfg, key, B=2, T=32):
    toks = jax.random.randint(key, (B, T + 1), 2, cfg.vocab)
    batch = {"labels": toks[:, 1:]}
    if cfg.frontend in ("audio_stub", "vision_stub"):
        # stub frontend: precomputed frame/patch embeddings
        batch["embeds"] = jax.random.normal(key, (B, T, cfg.d_model),
                                            cfg.cdtype) * 0.02
    else:
        batch["tokens"] = toks[:, :-1]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, preset="smoke")
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    logits, aux = model(params, batch.get("tokens"),
                        inputs_embeds=batch.get("embeds"))
    B = batch["labels"].shape[0]
    T = batch["labels"].shape[1]
    assert logits.shape == (B, T, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in gleaves)
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = model.loss(params2, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ["yi-9b", "qwen2-1.5b", "gemma3-4b"])
def test_arch_smoke_with_mosa_variant(arch):
    """The paper's technique toggles onto any attention arch."""
    cfg = get_config(arch, preset="smoke").with_mosa(sparsity=4, n_mosa_heads=4)
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_mosa_inapplicable_to_xlstm():
    cfg = get_config("xlstm-125m", preset="smoke")
    with pytest.raises(ValueError, match="inapplicable"):
        cfg.with_mosa()


def test_all_assigned_archs_registered():
    names = config_names()
    for a in ARCHS:
        assert a in names


FULL_EXPECT = {
    # (n_layers, d_model, n_heads, n_kv, d_ff, vocab) from the assignment
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "yi-34b": (60, 7168, 56, 8, 20480, 64000),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch, preset="full")
    want = FULL_EXPECT[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.attention.n_heads,
           cfg.attention.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == want, f"{arch}: {got} != {want}"


@pytest.mark.parametrize("arch,moe", [
    ("granite-moe-1b-a400m", (32, 8)),
    ("deepseek-v2-lite-16b", (64, 6)),
    ("jamba-v0.1-52b", (16, 2)),
])
def test_moe_configs(arch, moe):
    cfg = get_config(arch, preset="full")
    assert (cfg.moe.n_experts, cfg.moe.top_k) == moe


def test_jamba_interleave_ratio():
    cfg = get_config("jamba-v0.1-52b", preset="full")
    pat = cfg.resolved_pattern()
    n_attn = sum(1 for b in pat if b.mixer == "attn")
    n_mamba = sum(1 for b in pat if b.mixer == "mamba")
    assert n_attn * 7 == n_mamba     # 1:7


def test_gemma3_local_global_ratio():
    cfg = get_config("gemma3-4b", preset="full")
    pat = cfg.resolved_pattern()
    n_local = sum(1 for b in pat if b.mixer == "attn_local")
    n_global = sum(1 for b in pat if b.mixer == "attn")
    assert n_global == 5 and n_local == 29   # 34 layers, 5:1 + remainder


def test_find_period_head_offset():
    """deepseek-style odd first layer must not kill the layer scan (it.9)."""
    from repro.nn.transformer import find_period
    from repro.configs.base import BlockSpec
    a, b = BlockSpec("attn", "dense"), BlockSpec("attn", "moe")
    assert find_period((a,) + (b,) * 26) == (1, 1, 26, 27)
    assert find_period((b,) * 8) == (0, 1, 8, 8)
    assert find_period((a, b, a, b, a, b)) == (0, 2, 3, 6)
    # no periodicity at all
    c = BlockSpec("mamba", "dense")
    assert find_period((a, b, c)) == (0, 0, 0, 0)


def test_dryrun_build_cfg_mosa_variant():
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    cfg, shape, note = dr.build_cfg("yi-9b", "train_4k", mosa=True)
    assert cfg.mosa is not None and cfg.mosa.n_dense_heads == 4
    assert "mosa_hybrid" in note
    cfg2, _, note2 = dr.build_cfg("yi-9b", "long_500k")
    assert cfg2.mosa is not None and cfg2.mosa.k_fixed == 512
