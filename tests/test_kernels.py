"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mosa_inputs(key, B, H, S, d, T, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, H, S, d), dtype)
    v = jax.random.normal(ks[2], (B, H, S, d), dtype)
    perm = jnp.stack([
        jnp.stack([jax.random.permutation(jax.random.fold_in(ks[3], b * H + h),
                                          T)[:S]
                   for h in range(H)]) for b in range(B)])
    idx = jnp.sort(perm, axis=-1).astype(jnp.int32)
    r = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H, S))).astype(jnp.float32)
    return q, k, v, idx, r


MOSA_CASES = [
    # (B, H, S, d, T)
    (1, 1, 8, 16, 32),
    (2, 3, 24, 20, 100),
    (1, 2, 128, 64, 1024),     # paper-typical: k=128, d_head=64
    (2, 4, 33, 48, 256),       # non-aligned S
    (1, 2, 256, 128, 4096),    # MXU-aligned
]


@pytest.mark.parametrize("B,H,S,d,T", MOSA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mosa_kernel_matches_oracle(B, H, S, d, T, dtype):
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(0), B, H, S, d, T, dtype)
    out = ops.mosa_attention(q, k, v, idx, r)
    want = ref.mosa_attention_ref(q, k, v, idx, r)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


BF16_SWEEP_CASES = [
    # (B, H, S, d, T) — spans small/odd/MXU-aligned shapes
    (1, 2, 32, 16, 128),
    (2, 2, 48, 36, 200),
    (1, 2, 64, 64, 512),
    (1, 4, 128, 128, 2048),
]


@pytest.mark.parametrize("B,H,S,d,T", BF16_SWEEP_CASES)
def test_mosa_kernel_bf16_error_vs_fp32_oracle(B, H, S, d, T):
    """bf16 kernel vs the fp32 oracle on identical (bf16-quantized) inputs.

    Bounds the *accumulated* low-precision error, not just kernel-vs-oracle
    drift at matched dtype: the only allowed error sources are the bf16
    rounding of the output and the kernel's internal precision choices.
    """
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(7), B, H, S, d, T,
                                   jnp.bfloat16)
    out = np.asarray(ops.mosa_attention(q, k, v, idx, r), np.float32)
    want = np.asarray(ref.mosa_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        idx, r), np.float32)
    err = np.abs(out - want).max()
    # outputs are O(1) convex combinations of v*r; one bf16 ulp is ~2^-8
    assert err < 5e-2, f"bf16 max err {err} at shape {(B, H, S, d, T)}"


def test_mosa_kernel_dense_equivalent_full_selection():
    """T == S with k = T (every token selected): MoSA must reduce exactly to
    dense causal attention — checked against BOTH oracles (mosa ref and the
    dense flash ref), so a selection-mask regression can't hide in a shared
    oracle bug."""
    B, H, T, d = 2, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    idx = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, H, T))
    r = jnp.ones((B, H, T), jnp.float32)

    out = np.asarray(ops.mosa_attention(q, k, v, idx, r))
    want_mosa = np.asarray(ref.mosa_attention_ref(q, k, v, idx, r))
    want_dense = np.asarray(ref.flash_attention_ref(q, k, v))
    np.testing.assert_allclose(out, want_mosa, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(out, want_dense, atol=2e-5, rtol=2e-5)


def test_mosa_kernel_router_scaling():
    """Doubling r doubles the output (scaling is fused post-softmax)."""
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(1), 1, 2, 16, 8, 64,
                                   jnp.float32)
    o1 = ops.mosa_attention(q, k, v, idx, r)
    o2 = ops.mosa_attention(q, k, v, idx, 2 * r)
    np.testing.assert_allclose(np.asarray(2 * o1), np.asarray(o2), rtol=1e-5)


def test_mosa_kernel_respects_index_mask():
    """A query may only see keys with smaller-or-equal original index."""
    B, H, S, d, T = 1, 1, 8, 16, 64
    q, k, v, idx, r = _mosa_inputs(jax.random.PRNGKey(2), B, H, S, d, T,
                                   jnp.float32)
    out1 = ops.mosa_attention(q, k, v, idx, r)
    # perturb the LAST selected token's k/v: rows before it must not change
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    out2 = ops.mosa_attention(q, k2, v2, idx, r)
    np.testing.assert_allclose(np.asarray(out1[:, :, :-1]),
                               np.asarray(out2[:, :, :-1]), atol=1e-5)


FLASH_CASES = [
    # (B, Hq, Hkv, Tq, Tk, d, window)
    (1, 2, 2, 16, 16, 8, 0),
    (2, 4, 2, 50, 50, 36, 0),
    (2, 4, 2, 50, 50, 36, 7),
    (1, 8, 1, 128, 128, 64, 0),     # MQA
    (1, 4, 4, 1, 77, 32, 0),        # decode
    (1, 4, 2, 1, 300, 64, 64),      # windowed decode
    (2, 2, 2, 256, 256, 128, 128),  # MXU-aligned with window
]


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,d,window", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_oracle(B, Hq, Hkv, Tq, Tk, d, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Tq, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Tk, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Tk, d), dtype)
    out = ops.flash_attention(q, k, v, window=window)
    want = ref.flash_attention_ref(q, k, v, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_kernel_block_shape_sweep():
    """Different BlockSpec tilings give identical results."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, T, d = 1, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    outs = [np.asarray(ops.flash_attention(q, k, v, block_q=bq, block_k=bk))
            for bq, bk in [(64, 64), (128, 128), (128, 64), (256, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5)


def test_mosa_layer_pallas_equals_einsum():
    from repro.configs.base import MoSAConfig
    from repro.core.mosa import MoSAAttention
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 32))
    cfg = MoSAConfig(n_mosa_heads=6, sparsity=8, n_dense_heads=0, d_head=16)
    m1 = MoSAAttention(32, cfg, impl="einsum")
    m2 = MoSAAttention(32, cfg, impl="pallas")
    p = m1.init(key)
    np.testing.assert_allclose(np.asarray(m1(p, x)), np.asarray(m2(p, x)),
                               atol=1e-5)
